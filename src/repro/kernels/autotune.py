"""Benchmark-driven block-size autotuner for the Pallas kernel packages.

Every ops wrapper in this tree hardcodes a blocking heuristic (``bm``/``bk``
for the matmul family, ``bkv`` for the attention family). Those heuristics
were picked analytically, not measured; this module replaces them with a
persisted measurement:

* ``tune(kernel, shape)`` times every candidate block configuration for one
  kernel at one exact shape and records the winner;
* the winners live in a per-device JSON cache (one file per
  ``(backend, device_kind)``, default ``~/.cache/repro/``, overridable with
  ``REPRO_AUTOTUNE_CACHE``) and are loaded into memory once per process —
  ops wrappers call :func:`best` at *trace* time, so lookups must be pure
  host-side dict reads;
* ``choose_engine(m, n, k)``/``record_engine`` back the measured TL-vs-packed
  dispatcher: ``bitlinear.apply(use_kernel="auto")`` resolves the engine per
  (M, N, K) matmul shape from recorded timings instead of a hard-coded
  heuristic (DESIGN.md §table-lookup). With no recorded entry every consumer
  falls back to its previous hard-coded default, so an absent cache file is
  exactly the pre-autotuner behavior.

Cache file format (versioned, one flat object per kernel). Version history:
v1 keyed the attention family on the contiguous cache length ``s``; v2 adds
the page-indirect variants under their own ``decode_attention.paged`` /
``prefill_append.paged`` namespaces keyed on ``(ps, nb)`` — page-pool block
sizes are measured against a different memory layout, so contiguous-tuned
entries must never leak into paged lookups (and the version bump drops every
v1 file whole rather than guessing at a migration):

    {"version": 2,
     "device": "cpu:cpu",
     "kernels": {
       "ternary_matmul": {"m128-n4096-k4096": {"knobs": {"bm":128,"bk":256},
                                                "us": 412.3}},
       "engine": {"m1-n4096-k4096": {"knobs": {"engine": "tl"},
                                      "us": 80.1,
                                      "losers": {"packed": 95.0}}}}}
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Any, Callable

_VERSION = 2

# In-memory store: {kernel: {shape_key: entry}}. Loaded lazily from the cache
# file; ops wrappers read it at trace time (host-side only, never traced).
_CACHE: dict[str, dict[str, dict]] | None = None
_CACHE_PATH: Path | None = None


def device_key() -> str:
    """Stable per-device identity the cache is keyed by (backend + kind)."""
    import jax

    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # noqa: BLE001 - no devices (e.g. docs build)
        kind = "unknown"
    return f"{jax.default_backend()}:{kind}".replace(" ", "_")


def cache_path() -> Path:
    """Resolve the cache file: env override, else per-device file under
    ``~/.cache/repro``."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    base = Path(os.environ.get("XDG_CACHE_HOME", Path.home() / ".cache"))
    return base / "repro" / f"autotune-{device_key()}.json"


def set_cache_path(path: str | os.PathLike | None) -> None:
    """Point the in-process store at ``path`` (None -> default resolution)
    and reload. Tests and benchmarks use this for hermetic cache files."""
    global _CACHE, _CACHE_PATH
    _CACHE = None
    _CACHE_PATH = Path(path) if path is not None else None


def _valid_kernels(raw) -> dict[str, dict[str, dict]] | None:
    """The ``kernels`` table from a parsed cache payload, or None when the
    payload is structurally unusable (wrong version, non-dict levels, entries
    without ``knobs``). Anything short of the documented two-level
    ``{kernel: {shape_key: {"knobs": {...}}}}`` shape is rejected whole —
    ``best``/``lookup`` run at trace time and must never hit a surprise."""
    if not isinstance(raw, dict) or raw.get("version") != _VERSION:
        return None
    kernels = raw.get("kernels", {})
    if not isinstance(kernels, dict):
        return None
    for entries in kernels.values():
        if not isinstance(entries, dict):
            return None
        for entry in entries.values():
            if not isinstance(entry, dict) or not isinstance(
                    entry.get("knobs"), dict):
                return None
    return kernels


def _store() -> dict[str, dict[str, dict]]:
    global _CACHE
    if _CACHE is None:
        path = _CACHE_PATH or cache_path()
        _CACHE = {}
        try:
            text = path.read_text()
        except OSError:
            return _CACHE  # absent cache == no tuned entries
        try:
            kernels = _valid_kernels(json.loads(text))
        except ValueError:
            kernels = None  # truncated / non-JSON
        if kernels is not None:
            _CACHE = kernels
        else:
            # corrupted or version-mismatched cache: drop it and atomically
            # rewrite a fresh empty payload so the next process doesn't
            # re-parse the garbage; tuning proceeds from the heuristics.
            try:
                _save()
            except OSError:
                pass  # read-only cache dir: stay on in-memory defaults
    return _CACHE


def _save() -> None:
    path = _CACHE_PATH or cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"version": _VERSION, "device": device_key(), "kernels": _store()}
    tmp = path.with_suffix(".tmp")
    tmp.write_text(json.dumps(payload, indent=1, sort_keys=True))
    tmp.replace(path)


def shape_key(**dims: int) -> str:
    """Canonical shape key, e.g. ``shape_key(m=8, n=4096, k=4096)`` ->
    ``"k4096-m8-n4096"`` (sorted so every caller agrees)."""
    return "-".join(f"{k}{v}" for k, v in sorted(dims.items()))


def lookup(kernel: str, key: str) -> dict | None:
    """Tuned knobs for (kernel, shape key), or None when never tuned."""
    entry = _store().get(kernel, {}).get(key)
    return dict(entry["knobs"]) if entry else None


def best(kernel: str, key: str, default: dict) -> dict:
    """Tuned knobs merged over ``default`` — the ops-wrapper entry point.

    Missing cache/entry returns ``default`` untouched, so the hard-coded
    heuristics remain the zero-state behavior.
    """
    tuned = lookup(kernel, key)
    return {**default, **tuned} if tuned else dict(default)


def record(kernel: str, key: str, knobs: dict, us: float, *,
           losers: dict | None = None, save: bool = True) -> None:
    entry: dict[str, Any] = {"knobs": dict(knobs), "us": float(us)}
    if losers:
        entry["losers"] = {k: float(v) for k, v in losers.items()}
    _store().setdefault(kernel, {})[key] = entry
    if save:
        _save()


# ---------------------------------------------------------------------------
# TL-vs-packed engine dispatch (measured, not guessed)
# ---------------------------------------------------------------------------


def choose_engine(m: int, n: int, k: int) -> str | None:
    """Measured engine for an [M, N] x [N, K] ternary matmul: ``"tl"``,
    ``"packed"``, or None when the shape was never benchmarked (callers fall
    back to the packed path)."""
    knobs = lookup("engine", shape_key(m=m, n=n, k=k))
    return knobs["engine"] if knobs else None


def record_engine(m: int, n: int, k: int, timings_us: dict[str, float], *,
                  save: bool = True) -> str:
    """Record per-engine timings for one matmul shape; returns the winner."""
    winner = min(timings_us, key=timings_us.get)
    losers = {e: t for e, t in timings_us.items() if e != winner}
    record("engine", shape_key(m=m, n=n, k=k), {"engine": winner},
           timings_us[winner], losers=losers, save=save)
    return winner


# ---------------------------------------------------------------------------
# Timing + sweep harness
# ---------------------------------------------------------------------------


def measure(fn: Callable[[], Any], *, reps: int = 3, warmup: int = 1) -> float:
    """Best-of-``reps`` wall time of ``fn`` in microseconds (device-synced)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return min(times) * 1e6


def _divisor_pow2(x: int, cap: int) -> list[int]:
    """Powers of two <= cap that divide x (>= 1 entries; 128-grid friendly)."""
    out = [c for c in (64, 128, 256, 512) if c <= cap and x % c == 0]
    return out or [min(128, cap)]


def _candidates(kernel: str, shape: dict) -> list[dict]:
    """Candidate knob grids per kernel package, filtered to ``shape``."""
    m = shape.get("m", 1)
    k = shape.get("k", 128)
    s = shape.get("s", 128)  # cache length (attention kernels)
    if kernel == "ternary_matmul":
        bms = sorted({min(b, _round8(m)) for b in (8, 32, 64, 128)})
        bks = sorted({b for b in (128, 256, 512) if b <= max(k, 128)})
        return [{"bm": bm, "bk": bk} for bm in bms for bk in bks]
    if kernel == "tl_gemv":
        bms = sorted({min(b, _round8(m)) for b in (8, 32, 64, 128)})
        bks = sorted({b for b in (128, 256, 512) if b <= max(k, 128)})
        return [{"bm": bm, "bk": bk} for bm in bms for bk in bks]
    if kernel == "fused_norm_quant":
        return [{"bm": bm} for bm in sorted({min(b, _round8(m))
                                             for b in (8, 32, 64, 128)})]
    if kernel == "decode_attention":
        return [{"bkv": bkv} for bkv in (128, 256, 512) if bkv <= max(s, 128)]
    if kernel == "prefill_append":
        return [{"bkv": bkv} for bkv in _divisor_pow2(s, max(s, 64))]
    if kernel in ("decode_attention.paged", "prefill_append.paged"):
        ps = shape.get("ps", 64)  # bkv must divide the page size
        return [{"bkv": bkv} for bkv in _divisor_pow2(ps, ps)]
    raise KeyError(f"no sweep defined for kernel {kernel!r}")


def _round8(m: int) -> int:
    return ((max(m, 1) + 7) // 8) * 8


def _runner(kernel: str, shape: dict) -> Callable[[dict], Callable[[], Any]]:
    """Build ``knobs -> thunk`` for one kernel at one shape (random inputs,
    constructed once and reused across the sweep)."""
    import numpy as np
    import jax.numpy as jnp

    rng = np.random.default_rng(0)

    if kernel in ("ternary_matmul", "tl_gemv"):
        from ..core.packing import pack2
        from ..core.tl_matmul import tl_indices
        from .ternary_matmul import ops as tm_ops
        from .tl_gemv import ops as tl_ops

        m, n, k = shape["m"], shape["n"], shape["k"]
        x = jnp.asarray(rng.integers(-127, 128, (m, n)), jnp.int8)
        xs = jnp.asarray(rng.uniform(0.01, 0.1, (m, 1)), jnp.float32)
        w_t = jnp.asarray(rng.integers(-1, 2, (n, k)), jnp.int8)
        ws = jnp.float32(0.02)
        if kernel == "ternary_matmul":
            wp = pack2(w_t)

            def make(knobs):
                return lambda: tm_ops.ternary_matmul(x, xs, wp, ws, **knobs)
        else:
            w_idx = tl_indices(pack2(w_t))

            def make(knobs):
                return lambda: tl_ops.tl_matmul(x, xs, w_idx, ws, **knobs)
        return make

    if kernel == "fused_norm_quant":
        from .fused_norm_quant import ops as nq_ops

        m, n = shape["m"], shape["n"]
        x = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
        gamma = jnp.asarray(rng.normal(size=(n,)), jnp.float32)

        def make(knobs):
            return lambda: nq_ops.norm_quant(x, gamma, impl="kernel", **knobs)
        return make

    if kernel == "decode_attention":
        from .decode_attention import ops as da_ops

        b, h, hk, d, s = (shape.get("b", 2), shape.get("h", 4),
                          shape.get("hk", 2), shape.get("d", 64), shape["s"])
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(b, hk, s, d)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(b, hk, s, d)), jnp.float32)
        pos = jnp.full((b,), s - 1, jnp.int32)

        def make(knobs):
            return lambda: da_ops.decode_attention(q, kc, vc, pos, **knobs)
        return make

    if kernel == "prefill_append":
        from .prefill_append import ops as pa_ops

        b, h, hk, d, s, c = (shape.get("b", 2), shape.get("h", 4),
                             shape.get("hk", 2), shape.get("d", 64),
                             shape["s"], shape.get("c", 64))
        q = jnp.asarray(rng.normal(size=(b, h, c, d)), jnp.float32)
        kn = jnp.asarray(rng.normal(size=(b, hk, c, d)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(b, hk, c, d)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(b, hk, s, d)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(b, hk, s, d)), jnp.float32)
        off = jnp.zeros((b,), jnp.int32)

        def make(knobs):
            return lambda: pa_ops.prefill_append(q, kn, vn, kc, vc, off, **knobs)
        return make

    if kernel == "decode_attention.paged":
        from .decode_attention import ops as da_ops

        b, h, hk, d = (shape.get("b", 2), shape.get("h", 4),
                       shape.get("hk", 2), shape.get("d", 64))
        ps, nb = shape.get("ps", 64), shape.get("nb", 4)
        pages = b * nb + 1  # + the shared garbage page at 0
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(pages, hk, ps, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(pages, hk, ps, d)), jnp.float32)
        pt = jnp.asarray(rng.permutation(pages - 1)[: b * nb]
                         .reshape(b, nb) + 1, jnp.int32)
        pos = jnp.full((b,), nb * ps - 1, jnp.int32)

        def make(knobs):
            return lambda: da_ops.decode_attention_paged(q, kp, vp, pt, pos,
                                                         **knobs)
        return make

    if kernel == "prefill_append.paged":
        from .prefill_append import ops as pa_ops

        b, h, hk, d, c = (shape.get("b", 2), shape.get("h", 4),
                          shape.get("hk", 2), shape.get("d", 64),
                          shape.get("c", 64))
        ps, nb = shape.get("ps", 64), shape.get("nb", 4)
        pages = b * nb + 1
        q = jnp.asarray(rng.normal(size=(b, h, c, d)), jnp.float32)
        kn = jnp.asarray(rng.normal(size=(b, hk, c, d)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(b, hk, c, d)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(pages, hk, ps, d)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(pages, hk, ps, d)), jnp.float32)
        pt = jnp.asarray(rng.permutation(pages - 1)[: b * nb]
                         .reshape(b, nb) + 1, jnp.int32)
        off = jnp.zeros((b,), jnp.int32)

        def make(knobs):
            return lambda: pa_ops.prefill_append_paged(q, kn, vn, kp, vp, pt,
                                                       off, **knobs)
        return make

    raise KeyError(f"no runner defined for kernel {kernel!r}")


def tune(kernel: str, shape: dict, *, reps: int = 3,
         force: bool = False) -> dict:
    """Sweep one kernel at one shape; persist and return the winning entry.

    Returns ``{"knobs": ..., "us": ..., "source": "cache"|"sweep"}``; an
    existing cache entry short-circuits the sweep unless ``force``.
    """
    key = shape_key(**shape)
    if not force:
        cached = _store().get(kernel, {}).get(key)
        if cached:
            return {**cached, "source": "cache"}
    make = _runner(kernel, shape)
    results = []
    for knobs in _candidates(kernel, shape):
        try:
            us = measure(make(knobs), reps=reps)
        except Exception:  # noqa: BLE001 - illegal block config for shape
            continue
        results.append((us, knobs))
    if not results:
        raise RuntimeError(f"no viable block config for {kernel} @ {key}")
    results.sort(key=lambda r: r[0])
    us, knobs = results[0]
    losers = {json.dumps(kn, sort_keys=True): t for t, kn in results[1:4]}
    record(kernel, key, knobs, us, losers=losers)
    return {"knobs": knobs, "us": us, "source": "sweep"}


SMOKE_SHAPES: dict[str, list[dict]] = {
    # tiny per-kernel shape sets for the CI cache smoke (seconds, not minutes)
    "ternary_matmul": [{"m": 8, "n": 64, "k": 128}],
    "tl_gemv": [{"m": 8, "n": 64, "k": 128}],
    "fused_norm_quant": [{"m": 8, "n": 64}],
    "decode_attention": [{"b": 2, "h": 4, "hk": 2, "d": 16, "s": 128}],
    "prefill_append": [{"b": 2, "h": 4, "hk": 2, "d": 16, "s": 128, "c": 64}],
    "decode_attention.paged": [
        {"b": 2, "h": 4, "hk": 2, "d": 16, "ps": 64, "nb": 2}],
    "prefill_append.paged": [
        {"b": 2, "h": 4, "hk": 2, "d": 16, "ps": 64, "nb": 2, "c": 64}],
}


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tune the tiny built-in shape set for every kernel")
    ap.add_argument("--cache", default=None, help="cache file override")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)
    if args.cache:
        set_cache_path(args.cache)
    shapes = SMOKE_SHAPES
    for kernel, shape_list in shapes.items():
        for shape in shape_list:
            r = tune(kernel, shape, reps=args.reps)
            print(f"{kernel} @ {shape_key(**shape)}: {r['knobs']} "
                  f"({r['us']:.1f} us, {r['source']})")
    print(f"cache: {_CACHE_PATH or cache_path()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
