"""Pure-jnp oracle for single-token KV-cache attention (decode step)."""

from __future__ import annotations

import jax.numpy as jnp

from ...core import ternary

_NEG = -1e30


def decode_attention_reference(
    q, k_cache, v_cache, pos, *, window: int = 0, softcap: float = 0.0,
    scale: float | None = None
):
    """q [B, H, D]; k/v cache [B, HK, M, D]; pos [B] (attend to <= pos).

    GQA via kv repetition; f32 score/softmax throughout. This is the oracle
    both the Pallas kernel and the XLA serving form are tested against.
    """
    b, h, d = q.shape
    hk, m = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    scale = scale if scale is not None else 1.0 / d**0.5
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    kq = jnp.repeat(k_cache, g, axis=1)  # [B, H, M, D]
    vq = jnp.repeat(v_cache, g, axis=1)
    s = jnp.einsum("bhd,bhmd->bhm", q, kq, preferred_element_type=jnp.float32)
    s = s.astype(jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(m)[None, :]
    mask = kpos <= pos[:, None]
    if window > 0:
        mask &= (pos[:, None] - kpos) < window
    s = jnp.where(mask[:, None], s, _NEG)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhm,bhmd->bhd", p.astype(q.dtype), vq)


def decode_attention_quant_reference(
    q, k_cache, v_cache, k_scale, v_scale, pos, *, window: int = 0,
    softcap: float = 0.0, scale: float | None = None
):
    """Int8-cache oracle: *defines* the quantized path's semantics as the
    dense oracle applied to the dequantized cache — each int8 row × its f32
    per-(slot, head, position) scale, cast once to the query dtype (exactly
    what the Pallas kernel does per VMEM block).

    k/v_cache [B, HK, M, D] int8; k/v_scale [B, HK, M] f32.
    """
    kd = ternary.dequantize_kv(k_cache, k_scale, q.dtype)
    vd = ternary.dequantize_kv(v_cache, v_scale, q.dtype)
    return decode_attention_reference(q, kd, vd, pos, window=window,
                                      softcap=softcap, scale=scale)
