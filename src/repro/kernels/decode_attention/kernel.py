"""Pallas TPU kernel: single-token q vs KV cache with frontier block skipping.

The decode twin of ``kernels/flash_attention`` (DESIGN.md §decode). One grid
step owns (slot·kv-head, kv-block); the online-softmax state (m, l, acc) for
the slot's query group lives in VMEM scratch across the kv-block loop, exactly
the prefill recurrence with the q axis collapsed to the GQA group.

Where the prefill kernel skips *upper-triangular* blocks, decode skips blocks
past each slot's **frontier**: the per-slot position vector is scalar-prefetched
into SMEM, and

  * ``pl.when`` predication — blocks with ``j*bkv > pos[b]`` (or entirely below
    the sliding-window foot) never execute their dot/softmax/aggregate body, so
    per-slot compute tracks the live context length, not the padded ``max_len``
    (the decode analogue of the paper's reversed-reorder work saving, §III-B);
  * the k/v ``index_map`` clamps past-frontier block indices to the frontier
    block — Pallas's pipeline never re-fetches a block whose index repeats, so
    the skipped blocks also cost no HBM traffic.

Slots at heterogeneous positions therefore coexist in one batched call: each
``b`` reads its own ``pos[b]`` frontier. GQA uses the same index-map trick as
the prefill kernel: q is pre-grouped to [B·HK, G, D] so the G query heads that
share a kv head contract against one streamed k/v block.

**Int8 cache path** (DESIGN.md §kv-cache): with ``quantized=True`` the k/v
operands are int8 with per-row f32 scales riding alongside as [B·HK, M]
arrays, blocked by the *same* clamped index map — so a skipped block's scales
move no HBM traffic either. The block is dequantized in VMEM right before the
QK matmul (``ternary.dequantize_kv`` semantics: f32 multiply, one cast to the
query dtype); full-precision K/V never exists in HBM, which is the point —
the phase is bound on cache bytes, and int8+scale halves them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core import ternary

_NEG_INF = -1e30


def _kernel(
    pos_ref, q_ref, k_ref, v_ref, *rest,
    scale: float, bkv: int, window: int, softcap: float, nkv: int, hk: int,
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
    bh = pl.program_id(0)
    j = pl.program_id(1)
    p = pos_ref[bh // hk]  # this slot's frontier position

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Frontier skip: only blocks intersecting [max(p-window+1, 0), p] run.
    jmax = p // bkv
    live = j <= jmax
    if window > 0:
        jmin = jnp.maximum(p - window + 1, 0) // bkv
        live = jnp.logical_and(live, j >= jmin)

    @pl.when(live)
    def _step():
        q = q_ref[0]  # [G, D]
        k = k_ref[0]  # [bkv, D]
        v = v_ref[0]
        if quantized:
            # in-VMEM dequant right before the QK matmul: the int8 block and
            # its per-row scales are all that ever crossed HBM.
            k = ternary.dequantize_kv(k, ks_ref[0], q_ref.dtype)
            v = ternary.dequantize_kv(v, vs_ref[0], q_ref.dtype)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [G, bkv]
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= p
        if window > 0:
            mask = jnp.logical_and(mask, p - kpos < window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(s - m_new[:, None])
        m_ref[...] = m_new
        l_ref[...] = l_prev * alpha + pexp.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            pexp.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == nkv - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _call(q, k, v, pos, scales, *, bkv, window, softcap, scale, interpret):
    """Shared pallas_call builder for the dense and int8-cache paths.

    ``scales`` is ``None`` (dense bf16 cache) or ``(k_scale, v_scale)`` — the
    [B*HK, M] f32 per-row side arrays of an int8 cache."""
    bhk, g, d = q.shape
    m = k.shape[1]
    b = pos.shape[0]
    hk = bhk // b
    assert m % bkv == 0, (m, bkv)
    scale = scale if scale is not None else 1.0 / d**0.5
    nkv = m // bkv
    quantized = scales is not None

    kern = functools.partial(
        _kernel, scale=scale, bkv=bkv, window=window, softcap=softcap,
        nkv=nkv, hk=hk, quantized=quantized,
    )

    def live_j(bh, j, pos_ref):
        # Clamp skipped indices into the live [window-foot, frontier] range: a
        # repeated block index is not re-fetched by the pipeline, so skipped
        # blocks — past the frontier or below the window foot — move no HBM
        # traffic either.
        p = pos_ref[bh // hk]
        lo = jnp.maximum(p - window + 1, 0) // bkv if window > 0 else 0
        return jnp.clip(j, lo, p // bkv)

    def kv_index(bh, j, pos_ref):
        return (bh, live_j(bh, j, pos_ref), 0)

    def scale_index(bh, j, pos_ref):
        # the scale side arrays ride the same clamped schedule as their blocks
        return (bh, live_j(bh, j, pos_ref))

    in_specs = [
        pl.BlockSpec((1, g, d), lambda bh, j, pos_ref: (bh, 0, 0)),
        pl.BlockSpec((1, bkv, d), kv_index),
        pl.BlockSpec((1, bkv, d), kv_index),
    ]
    operands = [pos, q, k, v]
    if quantized:
        in_specs += [pl.BlockSpec((1, bkv), scale_index),
                     pl.BlockSpec((1, bkv), scale_index)]
        operands += list(scales)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bhk, nkv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, g, d), lambda bh, j, pos_ref: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bhk, g, d), q.dtype),
        interpret=interpret,
    )(*operands)


def _paged_kernel(pt_ref, pos_ref, *args, **kw):
    # Page-indirect wrapper: the page table rides as a second scalar-prefetch
    # operand consumed *only* by the index maps — the online-softmax body is
    # the contiguous kernel unchanged (logical kv positions are j*bkv+iota
    # whatever pool row the block was fetched from).
    del pt_ref
    return _kernel(pos_ref, *args, **kw)


def _call_paged(q, k, v, page_table, pos, scales, *, bkv, window, softcap,
                scale, interpret):
    """Page-indirect pallas_call builder (DESIGN.md §paged-kv).

    ``k``/``v`` are page pools reshaped to [P*HK, ps, D] (row = page·HK +
    kv-head) and ``page_table`` [B, NB] maps each slot's logical kv block to
    a page. The kv index map composes the contiguous frontier clamp with a
    table lookup: logical block ``lj`` → page ``pt[slot, lj·bkv ÷ ps]`` →
    pool row — so skipped blocks still repeat a block index and move zero
    bytes, page lookup included. ``scales`` pools are [P*HK, ps]."""
    bhk, g, d = q.shape
    p_hk, ps, _ = k.shape
    b, nb = page_table.shape
    hk = bhk // b
    assert ps % bkv == 0, (ps, bkv)
    scale = scale if scale is not None else 1.0 / d**0.5
    nkv = nb * ps // bkv
    quantized = scales is not None

    kern = functools.partial(
        _paged_kernel, scale=scale, bkv=bkv, window=window, softcap=softcap,
        nkv=nkv, hk=hk, quantized=quantized,
    )

    def live_j(bh, j, pt_ref, pos_ref):
        # same clamp as the contiguous kernel: skipped blocks repeat an index
        p = pos_ref[bh // hk]
        lo = jnp.maximum(p - window + 1, 0) // bkv if window > 0 else 0
        return jnp.clip(j, lo, p // bkv)

    def kv_index(bh, j, pt_ref, pos_ref):
        lj = live_j(bh, j, pt_ref, pos_ref)
        page = pt_ref[bh // hk, (lj * bkv) // ps]
        return (page * hk + bh % hk, lj % (ps // bkv), 0)

    def scale_index(bh, j, pt_ref, pos_ref):
        lj = live_j(bh, j, pt_ref, pos_ref)
        page = pt_ref[bh // hk, (lj * bkv) // ps]
        return (page * hk + bh % hk, lj % (ps // bkv))

    in_specs = [
        pl.BlockSpec((1, g, d), lambda bh, j, pt_ref, pos_ref: (bh, 0, 0)),
        pl.BlockSpec((1, bkv, d), kv_index),
        pl.BlockSpec((1, bkv, d), kv_index),
    ]
    operands = [page_table, pos, q, k, v]
    if quantized:
        in_specs += [pl.BlockSpec((1, bkv), scale_index),
                     pl.BlockSpec((1, bkv), scale_index)]
        operands += list(scales)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bhk, nkv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, g, d),
                               lambda bh, j, pt_ref, pos_ref: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bhk, g, d), q.dtype),
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit, static_argnames=("bkv", "window", "softcap", "scale", "interpret")
)
def decode_attention_paged_kernel(
    q: jax.Array,           # [B*HK, G, D] grouped queries
    k: jax.Array,           # [P*HK, ps, D] page pool
    v: jax.Array,           # [P*HK, ps, D]
    page_table: jax.Array,  # [B, NB] int32
    pos: jax.Array,         # [B] int32 per-slot frontier
    *,
    bkv: int = 128,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _call_paged(q, k, v, page_table, pos, None, bkv=bkv, window=window,
                       softcap=softcap, scale=scale, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("bkv", "window", "softcap", "scale", "interpret")
)
def decode_attention_paged_kernel_quant(
    q: jax.Array,           # [B*HK, G, D] grouped queries
    k: jax.Array,           # [P*HK, ps, D] int8 page pool
    v: jax.Array,           # [P*HK, ps, D]
    k_scale: jax.Array,     # [P*HK, ps] f32 per-row scales
    v_scale: jax.Array,     # [P*HK, ps]
    page_table: jax.Array,  # [B, NB] int32
    pos: jax.Array,         # [B] int32 per-slot frontier
    *,
    bkv: int = 128,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Int8-pool twin of :func:`decode_attention_paged_kernel`."""
    return _call_paged(q, k, v, page_table, pos, (k_scale, v_scale), bkv=bkv,
                       window=window, softcap=softcap, scale=scale,
                       interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("bkv", "window", "softcap", "scale", "interpret")
)
def decode_attention_kernel(
    q: jax.Array,    # [B*HK, G, D] grouped queries (G padded to sublane)
    k: jax.Array,    # [B*HK, M, D] cache (M padded to a bkv multiple)
    v: jax.Array,    # [B*HK, M, D]
    pos: jax.Array,  # [B] int32 per-slot frontier
    *,
    bkv: int = 128,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    return _call(q, k, v, pos, None, bkv=bkv, window=window, softcap=softcap,
                 scale=scale, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("bkv", "window", "softcap", "scale", "interpret")
)
def decode_attention_kernel_quant(
    q: jax.Array,        # [B*HK, G, D] grouped queries
    k: jax.Array,        # [B*HK, M, D] int8 cache
    v: jax.Array,        # [B*HK, M, D] int8 cache
    k_scale: jax.Array,  # [B*HK, M] f32 per-row scales
    v_scale: jax.Array,  # [B*HK, M]
    pos: jax.Array,      # [B] int32 per-slot frontier
    *,
    bkv: int = 128,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Int8-cache twin of :func:`decode_attention_kernel`: blocks are
    dequantized in VMEM right before the QK matmul."""
    return _call(q, k, v, pos, (k_scale, v_scale), bkv=bkv, window=window,
                 softcap=softcap, scale=scale, interpret=interpret)
