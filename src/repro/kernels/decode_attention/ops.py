"""Jitted wrapper + analytic schedule model for the decode attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import _common as C
from .. import autotune
from .kernel import (decode_attention_kernel, decode_attention_kernel_quant,
                     decode_attention_paged_kernel,
                     decode_attention_paged_kernel_quant)


def decode_attention(
    q: jax.Array,        # [B, H, D] single new token per slot
    k_cache: jax.Array,  # [B, HK, M, D] (bf16/f32, or int8 with scales)
    v_cache: jax.Array,  # [B, HK, M, D]
    pos: jax.Array,      # [B] (or scalar) attend-to-<=pos frontier
    *,
    k_scale: jax.Array | None = None,  # [B, HK, M] f32 (int8 cache only)
    v_scale: jax.Array | None = None,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    bkv: int | None = None,
    interpret=None,
) -> jax.Array:
    """Fused decode attention; returns [B, H, D].

    Pads the cache length to a ``bkv`` multiple (padded keys sit past every
    slot's frontier, so the in-kernel mask discards them) and the GQA group to
    the 8-row sublane (padded q rows are sliced away). With ``k_scale`` /
    ``v_scale`` set the caches are int8 and dequantized per block in VMEM
    (DESIGN.md §kv-cache); padded scale rows are zero, which dequantizes to
    zero K/V — masked out like any past-frontier key.
    """
    interpret = C.resolve_interpret(interpret)
    b, h, d = q.shape
    hk, m = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    quantized = k_scale is not None

    if bkv is None:
        bkv = autotune.best(
            "decode_attention",
            autotune.shape_key(b=b, h=h, hk=hk, d=d, s=m),
            {"bkv": 128})["bkv"]
    bkv = min(bkv, C.round_up(m, 128))
    mp = C.round_up(m, bkv)
    if mp != m:
        pad = ((0, 0), (0, 0), (0, mp - m), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
        if quantized:
            spad = ((0, 0), (0, 0), (0, mp - m))
            k_scale = jnp.pad(k_scale, spad)
            v_scale = jnp.pad(v_scale, spad)

    gp = C.round_up(g, 8)  # sublane shape for the grouped-query block
    qg = q.reshape(b, hk, g, d)
    if gp != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - g), (0, 0)))

    if quantized:
        out = decode_attention_kernel_quant(
            qg.reshape(b * hk, gp, d),
            k_cache.reshape(b * hk, mp, d),
            v_cache.reshape(b * hk, mp, d),
            k_scale.reshape(b * hk, mp).astype(jnp.float32),
            v_scale.reshape(b * hk, mp).astype(jnp.float32),
            pos,
            bkv=bkv, window=window, softcap=softcap, scale=scale,
            interpret=interpret,
        )
    else:
        out = decode_attention_kernel(
            qg.reshape(b * hk, gp, d),
            k_cache.reshape(b * hk, mp, d),
            v_cache.reshape(b * hk, mp, d),
            pos,
            bkv=bkv, window=window, softcap=softcap, scale=scale,
            interpret=interpret,
        )
    return out.reshape(b, hk, gp, d)[:, :, :g].reshape(b, h, d)


def decode_attention_paged(
    q: jax.Array,           # [B, H, D] single new token per slot
    k_pool: jax.Array,      # [P, HK, ps, D] page pool (bf16, or int8 + scales)
    v_pool: jax.Array,      # [P, HK, ps, D]
    page_table: jax.Array,  # [B, NB] int32 (NB·ps = logical cache length)
    pos: jax.Array,         # [B] attend-to-<=pos frontier
    *,
    k_scale: jax.Array | None = None,  # [P, HK, ps] f32 (int8 pool only)
    v_scale: jax.Array | None = None,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    bkv: int | None = None,
    interpret=None,
) -> jax.Array:
    """Page-indirect decode attention (DESIGN.md §paged-kv); returns [B, H, D].

    The contiguous kernel's frontier-skip schedule with its kv index map
    composed with a page-table lookup. ``bkv`` is tuned under its own
    ``decode_attention.paged`` autotune namespace (contiguous-tuned block
    sizes never leak in — they were measured against a different memory
    layout) and must divide the page size, so it is halved until it does.
    """
    interpret = C.resolve_interpret(interpret)
    b, h, d = q.shape
    p_pages, hk, ps = k_pool.shape[:3]
    nb = page_table.shape[1]
    g = h // hk
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    page_table = page_table.astype(jnp.int32)
    quantized = k_scale is not None

    if bkv is None:
        bkv = autotune.best(
            "decode_attention.paged",
            autotune.shape_key(b=b, h=h, hk=hk, d=d, ps=ps, nb=nb),
            {"bkv": min(ps, 128)})["bkv"]
    bkv = min(bkv, ps)
    while ps % bkv:
        bkv //= 2

    gp = C.round_up(g, 8)  # sublane shape for the grouped-query block
    qg = q.reshape(b, hk, g, d)
    if gp != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - g), (0, 0)))

    if quantized:
        out = decode_attention_paged_kernel_quant(
            qg.reshape(b * hk, gp, d),
            k_pool.reshape(p_pages * hk, ps, d),
            v_pool.reshape(p_pages * hk, ps, d),
            k_scale.reshape(p_pages * hk, ps).astype(jnp.float32),
            v_scale.reshape(p_pages * hk, ps).astype(jnp.float32),
            page_table, pos,
            bkv=bkv, window=window, softcap=softcap, scale=scale,
            interpret=interpret,
        )
    else:
        out = decode_attention_paged_kernel(
            qg.reshape(b * hk, gp, d),
            k_pool.reshape(p_pages * hk, ps, d),
            v_pool.reshape(p_pages * hk, ps, d),
            page_table, pos,
            bkv=bkv, window=window, softcap=softcap, scale=scale,
            interpret=interpret,
        )
    return out.reshape(b, hk, gp, d)[:, :, :g].reshape(b, h, d)


def schedule_blocks(pos, max_len: int, *, bkv: int = 128, window: int = 0):
    """Analytic kv-block counts for one decode step (per slot·kv-head).

    Returns ``(live, dense)``: blocks the frontier-skipping schedule runs vs
    the dense schedule's ``ceil(max_len / bkv)``. This is the decode analogue
    of ``benchmarks.bench_attention_schedule.schedule_counts`` and what
    ``benchmarks/bench_decode.py`` reports.
    """
    import numpy as np

    pos = np.atleast_1d(np.asarray(pos))
    dense = -(-max_len // bkv)
    jmax = np.minimum(pos // bkv, dense - 1)
    jmin = np.zeros_like(jmax)
    if window > 0:
        jmin = np.maximum(pos - window + 1, 0) // bkv
    live = (jmax - jmin + 1).astype(np.int64)
    return int(live.sum()), int(dense * pos.size)
