"""Jitted wrapper + analytic schedule model for the decode attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import _common as C
from .kernel import decode_attention_kernel


def decode_attention(
    q: jax.Array,        # [B, H, D] single new token per slot
    k_cache: jax.Array,  # [B, HK, M, D]
    v_cache: jax.Array,  # [B, HK, M, D]
    pos: jax.Array,      # [B] (or scalar) attend-to-<=pos frontier
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    bkv: int = 128,
    interpret=None,
) -> jax.Array:
    """Fused decode attention; returns [B, H, D].

    Pads the cache length to a ``bkv`` multiple (padded keys sit past every
    slot's frontier, so the in-kernel mask discards them) and the GQA group to
    the 8-row sublane (padded q rows are sliced away).
    """
    interpret = C.resolve_interpret(interpret)
    b, h, d = q.shape
    hk, m = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))

    bkv = min(bkv, C.round_up(m, 128))
    mp = C.round_up(m, bkv)
    if mp != m:
        pad = ((0, 0), (0, 0), (0, mp - m), (0, 0))
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)

    gp = C.round_up(g, 8)  # sublane shape for the grouped-query block
    qg = q.reshape(b, hk, g, d)
    if gp != g:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - g), (0, 0)))

    out = decode_attention_kernel(
        qg.reshape(b * hk, gp, d),
        k_cache.reshape(b * hk, mp, d),
        v_cache.reshape(b * hk, mp, d),
        pos,
        bkv=bkv, window=window, softcap=softcap, scale=scale,
        interpret=interpret,
    )
    return out.reshape(b, hk, gp, d)[:, :, :g].reshape(b, h, d)


def schedule_blocks(pos, max_len: int, *, bkv: int = 128, window: int = 0):
    """Analytic kv-block counts for one decode step (per slot·kv-head).

    Returns ``(live, dense)``: blocks the frontier-skipping schedule runs vs
    the dense schedule's ``ceil(max_len / bkv)``. This is the decode analogue
    of ``benchmarks.bench_attention_schedule.schedule_counts`` and what
    ``benchmarks/bench_decode.py`` reports.
    """
    import numpy as np

    pos = np.atleast_1d(np.asarray(pos))
    dense = -(-max_len // bkv)
    jmax = np.minimum(pos // bkv, dense - 1)
    jmin = np.zeros_like(jmax)
    if window > 0:
        jmin = np.maximum(pos - window + 1, 0) // bkv
    live = (jmax - jmin + 1).astype(np.int64)
    return int(live.sum()), int(dense * pos.size)
