"""Fused single-token KV-cache attention (decode fast path).

Pallas twin of ``models.attention.decode_attention``: one new query token per
slot against a ring of cached K/V, with per-slot frontier block skipping so
cost tracks the *live* context length rather than the padded ``max_len`` —
the decode analogue of the prefill kernel's reverse/causal-skip schedule.
"""

from .ops import decode_attention, schedule_blocks  # noqa: F401
from .ref import (  # noqa: F401
    decode_attention_quant_reference,
    decode_attention_reference,
)
