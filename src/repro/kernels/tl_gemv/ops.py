"""Jitted wrappers for the table-lookup matmul engine (TeLLMe Algorithm 1).

``tl_gemv`` is the original decode wrapper; ``tl_matmul`` / ``tl_swiglu``
are the end-to-end engine entry points: multi-row M, per-output-channel
weight scales, fused residual / requant epilogues, and an optional
``tables`` operand carrying the fused norm-quant prologue's precomputed
group tables (the paper's online precomputation, hoisted out of the matmul).

Block sizes default to the autotuner's persisted winners for the exact call
shape (``kernels.autotune``), falling back to the same fixed heuristics the
packed wrappers use when no measurement is recorded.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import _common as C
from .. import autotune
from .kernel import tl_gemv_kernel, tl_matmul_kernel, tl_swiglu_kernel


def tl_gemv(x_i8, x_scale, w_idx, w_scale, *, g: int = 3, bk: int | None = None,
            interpret=None, out_dtype=jnp.float32):
    """x_i8 [..., N] int8 × group-index weights [N/g, K] -> [..., K].

    ``w_scale`` is a scalar (per-tensor absmean) *or* a per-output-channel
    vector ([K] or [1, K]) — parity with ``ternary_matmul_ref``'s dequant
    contract, so per-channel-scaled packed layers can take the TL path too.
    ``bk`` tunes the K-block streamed per grid step (K is padded up to a
    ``bk`` multiple here and sliced back after the call; pad columns carry a
    zero scale, so they cost nothing beyond the padded lanes). ``bk=None``
    reads the autotuner's winner for this shape (default 128).
    """
    interpret = C.resolve_interpret(interpret)
    x2, lead, m = C.flatten_lead(x_i8)
    s2 = x_scale.reshape(m, 1)
    t, k = w_idx.shape
    if bk is None:
        bk = autotune.best("tl_gemv", autotune.shape_key(m=m, n=t * g, k=k),
                           {"bk": 128})["bk"]
    kp = C.round_up(k, bk)
    w2 = C.pad_to(w_idx, 1, kp)
    # scalar -> broadcast row; [K] / [1, K] -> per-channel row (zero-padded)
    ws = jnp.broadcast_to(
        jnp.asarray(w_scale, jnp.float32).reshape(1, -1), (1, k))
    ws2 = C.pad_to(ws, 1, kp)
    out = tl_gemv_kernel(x2, s2, w2, ws2, g=g, bk=bk, interpret=interpret)
    return out[:, :k].reshape(*lead, k).astype(out_dtype)


def _zero_group_index(g: int) -> int:
    """Base-3 index of the all-zero-trit group (biased digits all 1)."""
    return (3**g - 1) // 2


def _pad_idx_cols(w_idx, kp: int, g: int):
    """Pad K columns with the all-zero-trit group index, so padded output
    channels stay exactly zero (the TL twin of ``_pad_packed_cols``)."""
    k = w_idx.shape[1]
    if k == kp:
        return w_idx
    return jnp.pad(w_idx, ((0, 0), (0, kp - k)),
                   constant_values=_zero_group_index(g))


def tl_matmul(x_i8, x_scale, w_idx, w_scale, *, g: int = 3,
              bm: int | None = None, bk: int | None = None, tables=None,
              residual=None, out_dtype=jnp.float32, impl: str = "auto",
              interpret=None):
    """Prefill-shaped TL matmul: x_i8 [..., N] × w_idx [⌈N/g⌉, K] -> [..., K].

    The TL twin of ``ternary_matmul``: leading dims flatten to M, M/K pad to
    block multiples, ``residual [..., K]`` rides the dequant epilogue, and
    ``w_scale`` may be per-tensor or per-channel. ``tables`` (the fused
    prologue's [..., T·3^g] precompute) replaces the in-kernel table build
    when given — ``x_i8`` may then be None.

    ``impl`` mirrors the packed dispatch: ``"kernel"`` the Pallas kernel,
    ``"xla"`` the bit-identical Algorithm-1 oracle (the CPU serving path —
    interpret-mode Pallas is an emulator, not a fast path), ``"auto"``
    kernel-on-TPU. Engine switches therefore never change results on any
    backend: the XLA TL form is exact against the packed XLA form, the TL
    kernel exact against the packed kernels.
    """
    if impl == "auto":
        impl = "kernel" if C.on_tpu() else "xla"
    if impl == "xla" and x_i8 is not None:
        from . import ref

        return ref.tl_matmul(x_i8, x_scale, w_idx, w_scale, g=g,
                             residual=residual, out_dtype=out_dtype)
    interpret = C.resolve_interpret(interpret)
    t, k = w_idx.shape
    if tables is not None:
        a2, lead, m = C.flatten_lead(tables)
        na = t * 3**g
        assert a2.shape[1] == na, (a2.shape, t, g)
    else:
        a2, lead, m = C.flatten_lead(x_i8)
        if a2.shape[1] < t * g:
            a2 = C.pad_to(a2, 1, t * g)
    s2 = x_scale.reshape(m, 1)
    knobs = autotune.best(
        "tl_gemv", autotune.shape_key(m=m, n=t * g, k=k), {"bm": 128, "bk": 128})
    bm = bm if bm is not None else knobs["bm"]
    bk = bk if bk is not None else knobs["bk"]
    bm = min(bm, C.round_up(m, 8))
    mp = C.round_up(m, bm)
    kp = C.round_up(k, bk)
    a2 = C.pad_to(a2, 0, mp)
    s2 = C.pad_to(s2, 0, mp)
    w2 = _pad_idx_cols(w_idx, kp, g)
    ws = jnp.broadcast_to(
        jnp.asarray(w_scale, jnp.float32).reshape(1, -1), (1, k))
    ws2 = C.pad_to(ws, 1, kp)
    r2 = None
    if residual is not None:
        r2 = C.pad_to(C.pad_to(
            residual.astype(out_dtype).reshape(m, k), 0, mp), 1, kp)
    out = tl_matmul_kernel(
        a2, s2, w2, ws2, r2, g=g, bm=bm, bk=bk,
        from_tables=tables is not None, out_dtype=out_dtype,
        interpret=interpret)
    return out[:m, :k].reshape(*lead, k)


def tl_swiglu(x_i8, x_scale, wg_idx, wg_scale, wu_idx, wu_scale, *,
              g: int = 3, bm: int | None = None, tables=None,
              act_dtype=jnp.bfloat16, impl: str = "auto", interpret=None):
    """Fused TL SwiGLU: int8 (or precomputed tables) in, int8 + scale out.

    The TL twin of ``ternary_swiglu``: gate/up lookups plus the dequant →
    SiLU → (×up) → requant epilogue in one kernel. Padded K columns carry
    the all-zero-trit group index, so they dequantize to exactly zero and
    cannot move the per-token absmax. ``impl`` as in :func:`tl_matmul` —
    ``"auto"`` runs the XLA oracle off-TPU (exact vs the packed XLA swiglu).
    """
    if impl == "auto":
        impl = "kernel" if C.on_tpu() else "xla"
    if impl == "xla" and x_i8 is not None:
        from . import ref

        return ref.tl_swiglu(x_i8, x_scale, wg_idx, wg_scale, wu_idx,
                             wu_scale, g=g, act_dtype=act_dtype)
    interpret = C.resolve_interpret(interpret)
    t, k = wg_idx.shape
    if tables is not None:
        a2, lead, m = C.flatten_lead(tables)
        assert a2.shape[1] == t * 3**g, (a2.shape, t, g)
    else:
        a2, lead, m = C.flatten_lead(x_i8)
        if a2.shape[1] < t * g:
            a2 = C.pad_to(a2, 1, t * g)
    knobs = autotune.best(
        "tl_gemv", autotune.shape_key(m=m, n=t * g, k=k), {"bm": 128})
    bm = bm if bm is not None else knobs.get("bm", 128)
    bm = min(bm, C.round_up(m, 8))
    mp = C.round_up(m, bm)
    a2 = C.pad_to(a2, 0, mp)
    s2 = C.pad_to(x_scale.reshape(m, 1), 0, mp)
    kp = C.round_up(k, 128)
    wg2 = _pad_idx_cols(wg_idx, kp, g)
    wu2 = _pad_idx_cols(wu_idx, kp, g)
    h_i8, h_s = tl_swiglu_kernel(
        a2, s2, wg2, jnp.asarray(wg_scale, jnp.float32).reshape(1, 1),
        wu2, jnp.asarray(wu_scale, jnp.float32).reshape(1, 1),
        g=g, bm=bm, from_tables=tables is not None, act_dtype=act_dtype,
        interpret=interpret)
    return h_i8[:m, :k].reshape(*lead, k), h_s[:m].reshape(*lead, 1)
