"""Jitted wrapper for the faithful TL-table GEMV kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import tl_gemv_kernel


def tl_gemv(x_i8, x_scale, w_idx, w_scale, *, g: int = 3, interpret=None, out_dtype=jnp.float32):
    """x_i8 [..., N] int8 × group-index weights [N/g, K] -> [..., K]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    *lead, n = x_i8.shape
    m = 1
    for d in lead:
        m *= d
    x2 = x_i8.reshape(m, n)
    s2 = x_scale.reshape(m, 1)
    t, k = w_idx.shape
    bk = 128
    kp = ((k + bk - 1) // bk) * bk
    w2 = jnp.pad(w_idx, ((0, 0), (0, kp - k))) if kp != k else w_idx
    ws = jnp.asarray(w_scale, jnp.float32).reshape(1, 1)
    out = tl_gemv_kernel(x2, s2, w2, ws, g=g, bk=bk, interpret=interpret)
    return out[:, :k].reshape(*lead, k).astype(out_dtype)
