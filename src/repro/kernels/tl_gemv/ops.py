"""Jitted wrapper for the faithful TL-table GEMV kernel."""

from __future__ import annotations

import jax.numpy as jnp

from .. import _common as C
from .kernel import tl_gemv_kernel


def tl_gemv(x_i8, x_scale, w_idx, w_scale, *, g: int = 3, bk: int = 128,
            interpret=None, out_dtype=jnp.float32):
    """x_i8 [..., N] int8 × group-index weights [N/g, K] -> [..., K].

    ``w_scale`` is a scalar (per-tensor absmean) *or* a per-output-channel
    vector ([K] or [1, K]) — parity with ``ternary_matmul_ref``'s dequant
    contract, so per-channel-scaled packed layers can take the TL path too.
    ``bk`` tunes the K-block streamed per grid step (K is padded up to a
    ``bk`` multiple here and sliced back after the call; pad columns carry a
    zero scale, so they cost nothing beyond the padded lanes).
    """
    interpret = C.resolve_interpret(interpret)
    x2, lead, m = C.flatten_lead(x_i8)
    s2 = x_scale.reshape(m, 1)
    t, k = w_idx.shape
    kp = C.round_up(k, bk)
    w2 = C.pad_to(w_idx, 1, kp)
    # scalar -> broadcast row; [K] / [1, K] -> per-channel row (zero-padded)
    ws = jnp.broadcast_to(
        jnp.asarray(w_scale, jnp.float32).reshape(1, -1), (1, k))
    ws2 = C.pad_to(ws, 1, kp)
    out = tl_gemv_kernel(x2, s2, w2, ws2, g=g, bk=bk, interpret=interpret)
    return out[:, :k].reshape(*lead, k).astype(out_dtype)
