"""Jitted wrapper for the faithful TL-table GEMV kernel."""

from __future__ import annotations

import jax.numpy as jnp

from .. import _common as C
from .kernel import tl_gemv_kernel


def tl_gemv(x_i8, x_scale, w_idx, w_scale, *, g: int = 3, interpret=None, out_dtype=jnp.float32):
    """x_i8 [..., N] int8 × group-index weights [N/g, K] -> [..., K]."""
    interpret = C.resolve_interpret(interpret)
    x2, lead, m = C.flatten_lead(x_i8)
    s2 = x_scale.reshape(m, 1)
    t, k = w_idx.shape
    bk = 128
    w2 = C.pad_to(w_idx, 1, C.round_up(k, bk))
    ws = jnp.asarray(w_scale, jnp.float32).reshape(1, 1)
    out = tl_gemv_kernel(x2, s2, w2, ws, g=g, bk=bk, interpret=interpret)
    return out[:, :k].reshape(*lead, k).astype(out_dtype)
