"""Oracles for the table-lookup engine = ``core.tl_matmul`` (the single
definition of TL semantics — group packing, zero-trit padding, table build)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import ternary
from ...core.tl_matmul import build_tables  # noqa: F401  (re-exported oracle)
from ...core.tl_matmul import tl_matmul as _tl


def _pad_groups(x_i8, t: int, g: int):
    n = x_i8.shape[-1]
    if n < t * g:
        pads = [(0, 0)] * (x_i8.ndim - 1) + [(0, t * g - n)]
        x_i8 = jnp.pad(x_i8, pads)
    return x_i8


def tl_gemv(x_i8, x_scale, w_idx, w_scale, *, g: int = 3, out_dtype=jnp.float32):
    return _tl(x_i8, x_scale, w_idx, w_scale, g=g, out_dtype=out_dtype)


def tl_matmul(x_i8, x_scale, w_idx, w_scale, *, g: int = 3, residual=None,
              out_dtype=jnp.float32):
    """Multi-row oracle: zero-trit pads the ragged contraction tail, then
    the exact Algorithm-1 integer path + shared dequant epilogue. Leading
    dims flatten to M (``core.tl_matmul`` is strictly 2-D); the residual is
    a plain post-add, exactly the packed XLA form."""
    t, k = w_idx.shape
    lead = x_i8.shape[:-1]
    x2 = _pad_groups(x_i8, t, g).reshape(-1, t * g)
    s2 = jnp.reshape(x_scale, (-1, 1))
    out = _tl(x2, s2, w_idx, w_scale, g=g, out_dtype=out_dtype)
    out = out.reshape(*lead, k)
    return out if residual is None else out + residual


def tl_swiglu(x_i8, x_scale, wg_idx, wg_scale, wu_idx, wu_scale, *,
              g: int = 3, act_dtype=jnp.bfloat16):
    """Unfused oracle of ``tl_swiglu_kernel``: two TL matmuls + the exact
    requant op sequence the packed swiglu paths share."""
    gate = tl_matmul(x_i8, x_scale, wg_idx, wg_scale, g=g, out_dtype=act_dtype)
    up = tl_matmul(x_i8, x_scale, wu_idx, wu_scale, g=g, out_dtype=act_dtype)
    return ternary.quantize_act(jax.nn.silu(gate) * up)
