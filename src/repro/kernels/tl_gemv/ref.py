"""Oracle for the faithful table-lookup GEMV kernel = core.tl_matmul."""

from __future__ import annotations

import jax.numpy as jnp

from ...core.tl_matmul import tl_matmul as _tl


def tl_gemv(x_i8, x_scale, w_idx, w_scale, *, g: int = 3, out_dtype=jnp.float32):
    return _tl(x_i8, x_scale, w_idx, w_scale, g=g, out_dtype=out_dtype)
