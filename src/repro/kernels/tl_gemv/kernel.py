"""Pallas kernels: faithful TeLLMe Algorithm-1 table-lookup ternary matmul.

This package is the faithful port of the paper's TL-based matmul (G-trit
group indices, 3^G-entry tables built online from the activations, lookup +
accumulate). TeLLMe v2 promotes it from a decode-only GEMV curiosity to the
*primary* engine for both phases, so three kernels live here:

* ``tl_gemv_kernel``     — the original decode GEMV (grid over K only,
  activations fully VMEM-resident);
* ``tl_matmul_kernel``   — the prefill-shaped generalization: grid
  (M/bm, K/bk), per-output-channel ``w_scale`` row, optional fused residual
  add, and an optional *precomputed-tables* input so the table build can be
  hoisted into the fused norm-quant prologue (the paper's "online
  precomputation" — tables are built once per token row and reused by every
  projection consuming that row);
* ``tl_swiglu_kernel``   — gate+up TL matmuls plus the dequant → SiLU →
  (×up) → absmax-int8 requant epilogue in one kernel, emitting int8 + scale
  so the TL engine slots into the int8-resident pipeline exactly like
  ``ternary_swiglu``.

Stage structure inside one grid step:

  1. table build — the paper's "precompute unit" of 3^G adder/subtractor
     combinations is literally the matmul  A_groups [bm·T, G] @ COMBOS [G, 3^G]
     (T = N/G tables, all built in one MXU call); skipped entirely when the
     prologue already delivered the tables;
  2. lookup-accumulate — TL_TABLE[t, W_idx[t, k]] summed over t, expressed as
     a one-hot contraction so it also lands on the MXU rather than a VPU
     gather (the TPU replacement for URAM multi-port reads).

All accumulation is f32 over exact small integers (|table entry| <= 3·127,
partial sums < 2^24 for any N <= 16384), so the TL engine is *bit-identical*
to the packed int32 path after the shared dequant epilogue ordering
``(acc · x_scale) · w_scale`` — the property the dispatcher relies on.

VMEM: tables [bm, T·3^G] f32 (e.g. N=4096, G=3, bm=128 -> 128·1366·27·4
≈ 18 MiB is too fat — ops.py drops bm for wide N), w_idx block [T, bk]
int32, out [bm, bk].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import ternary
from ...core.packing import combo_matrix_np


def _build_tables(x, combos, *, g: int, t: int):
    """In-kernel stage 1: int8 rows [bm, n<=t·g] -> tables [bm, t, 3^g] f32."""
    bm, n = x.shape
    if n < t * g:  # ragged contraction tail: zero trits pad the last group
        x = jnp.concatenate(
            [x, jnp.zeros((bm, t * g - n), x.dtype)], axis=1)
    a_groups = x.reshape(bm * t, g).astype(jnp.float32)
    return jax.lax.dot_general(
        a_groups, combos, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bm, t, 3**g)


def _lookup_acc(tables, idx):
    """In-kernel stage 2: tables [bm, t, 3^g] × idx [t, bk] -> acc [bm, bk]
    f32, as a one-hot MXU contraction."""
    bm, t, c = tables.shape
    bk = idx.shape[1]
    onehot = (idx[:, :, None] == jnp.arange(c, dtype=jnp.int32)[None, None, :]
              ).astype(jnp.float32)  # [t, bk, 3^g]
    return jax.lax.dot_general(
        tables.reshape(bm, t * c),
        onehot.transpose(0, 2, 1).reshape(t * c, bk),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _kernel(x_ref, xs_ref, widx_ref, ws_ref, combos_ref, o_ref, *, g: int):
    t = widx_ref.shape[0]
    tables = _build_tables(x_ref[...], combos_ref[...], g=g, t=t)
    acc = _lookup_acc(tables, widx_ref[...])
    # dequant epilogue: per-token activation scale × per-output-channel (or
    # broadcast per-tensor) weight scale row for this K block
    o_ref[...] = acc * xs_ref[...] * ws_ref[...]


@functools.partial(jax.jit, static_argnames=("g", "bk", "interpret"))
def tl_gemv_kernel(
    x_i8: jax.Array,  # [M, N] int8 (M small; decode GEMV)
    x_scale: jax.Array,  # [M, 1] f32
    w_idx: jax.Array,  # [N/g, K] int32 group indices
    w_scale: jax.Array,  # [1, K] f32 per-output-channel scale row
    *,
    g: int = 3,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, n = x_i8.shape
    t, k = w_idx.shape
    assert t * g == n and k % bk == 0 and w_scale.shape == (1, k)
    combos = combo_matrix_np(g)
    return pl.pallas_call(
        functools.partial(_kernel, g=g),
        grid=(k // bk,),
        in_specs=[
            pl.BlockSpec((m, n), lambda j: (0, 0)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
            pl.BlockSpec((t, bk), lambda j: (0, j)),
            pl.BlockSpec((1, bk), lambda j: (0, j)),
            pl.BlockSpec((g, 3**g), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
    )(x_i8, x_scale, w_idx, w_scale, combos)


def _mm_kernel(a_ref, xs_ref, widx_ref, ws_ref, *rest, g: int,
               from_tables: bool, residual: bool, out_dtype):
    o_ref = rest[-1]
    t = widx_ref.shape[0]
    if from_tables:
        bm = a_ref.shape[0]
        tables = a_ref[...].reshape(bm, t, 3**g)
    else:
        tables = _build_tables(a_ref[...], rest[0][...], g=g, t=t)
    acc = _lookup_acc(tables, widx_ref[...])
    out = (acc * xs_ref[...] * ws_ref[...]).astype(out_dtype)
    if residual:
        # residual add on the VMEM block, same dtype arithmetic as the
        # unfused ``out + r`` (parity with ternary_matmul_kernel)
        out = out + rest[-2][...]
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=(
    "g", "bm", "bk", "from_tables", "out_dtype", "interpret"))
def tl_matmul_kernel(
    a: jax.Array,  # [M, N] int8 activations, or [M, T·3^g] f32 tables
    x_scale: jax.Array,  # [M, 1] f32
    w_idx: jax.Array,  # [T, K] int32 group indices (T = ⌈N/g⌉)
    w_scale: jax.Array,  # [1, K] f32 per-output-channel scale row
    residual: jax.Array | None = None,  # [M, K] out_dtype, added in-epilogue
    *,
    g: int = 3,
    bm: int = 128,
    bk: int = 128,
    from_tables: bool = False,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Prefill-shaped TL matmul: grid (M/bm, K/bk).

    With ``from_tables`` the first operand is the prologue's precomputed
    table block (stage 1 skipped entirely); otherwise tables are built
    in-kernel from the int8 block. Either way the result is bit-identical to
    the packed kernel at the same shape.
    """
    m = a.shape[0]
    t, k = w_idx.shape
    na = a.shape[1]
    # int8 input may stop short of t·g: the last (ragged) group is zero-trit
    # padded inside the kernel, mirroring tl_indices' weight-side padding
    assert (na == t * 3**g if from_tables
            else (t - 1) * g < na <= t * g), (na, t, g, from_tables)
    assert m % bm == 0 and k % bk == 0 and w_scale.shape == (1, k)
    has_r = residual is not None
    in_specs = [
        pl.BlockSpec((bm, na), lambda i, j: (i, 0)),
        pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        pl.BlockSpec((t, bk), lambda i, j: (0, j)),
        pl.BlockSpec((1, bk), lambda i, j: (0, j)),
    ]
    args = [a, x_scale, w_idx, w_scale]
    if not from_tables:
        in_specs.append(pl.BlockSpec((g, 3**g), lambda i, j: (0, 0)))
        args.append(combo_matrix_np(g))
    if has_r:
        in_specs.append(pl.BlockSpec((bm, bk), lambda i, j: (i, j)))
        args.append(residual)
    return pl.pallas_call(
        functools.partial(_mm_kernel, g=g, from_tables=from_tables,
                          residual=has_r, out_dtype=out_dtype),
        grid=(m // bm, k // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), out_dtype),
        interpret=interpret,
    )(*args)


def _swiglu_kernel(a_ref, xs_ref, wg_ref, wgs_ref, wu_ref, wus_ref, *rest,
                   g: int, from_tables: bool, act_dtype):
    i8_ref, s_ref = rest[-2], rest[-1]
    t = wg_ref.shape[0]
    if from_tables:
        bm = a_ref.shape[0]
        tables = a_ref[...].reshape(bm, t, 3**g)
    else:
        tables = _build_tables(a_ref[...], rest[0][...], g=g, t=t)
    xs = xs_ref[...]
    gate = (_lookup_acc(tables, wg_ref[...]) * xs * wgs_ref[0, 0]).astype(act_dtype)
    up = (_lookup_acc(tables, wu_ref[...]) * xs * wus_ref[0, 0]).astype(act_dtype)
    # dequant → SiLU → (×up) → requant, op-for-op the packed swiglu kernel's
    # epilogue, so the int8 codes are bit-identical across engines
    h_i8, h_s = ternary.quantize_act(jax.nn.silu(gate) * up)
    i8_ref[...] = h_i8
    s_ref[...] = h_s


@functools.partial(jax.jit, static_argnames=(
    "g", "bm", "from_tables", "act_dtype", "interpret"))
def tl_swiglu_kernel(
    a: jax.Array,  # [M, N] int8 activations, or [M, T·3^g] f32 tables
    x_scale: jax.Array,  # [M, 1] f32
    wg_idx: jax.Array,  # [T, K] int32 gate group indices
    wg_scale: jax.Array,  # [1, 1] f32
    wu_idx: jax.Array,  # [T, K] int32 up group indices
    wu_scale: jax.Array,  # [1, 1] f32
    *,
    g: int = 3,
    bm: int = 128,
    from_tables: bool = False,
    act_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """TL twin of ``ternary_swiglu_kernel``: (h_i8 [M, K], h_scale [M, 1]).

    Grid runs over M only — both index matrices' full K resident per step —
    so the requant absmax sees the whole hidden row (the scale is exactly
    the unfused one). Padded K columns must carry the all-zero-trit group
    index so they cannot move the absmax (ops.py's ``_pad_idx_cols``).
    """
    m = a.shape[0]
    t, k = wg_idx.shape
    na = a.shape[1]
    assert (na == t * 3**g if from_tables
            else (t - 1) * g < na <= t * g), (na, t, g, from_tables)
    assert wu_idx.shape == wg_idx.shape and m % bm == 0
    in_specs = [
        pl.BlockSpec((bm, na), lambda i: (i, 0)),
        pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        pl.BlockSpec((t, k), lambda i: (0, 0)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
        pl.BlockSpec((t, k), lambda i: (0, 0)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
    ]
    args = [a, x_scale, wg_idx, wg_scale, wu_idx, wu_scale]
    if not from_tables:
        in_specs.append(pl.BlockSpec((g, 3**g), lambda i: (0, 0)))
        args.append(combo_matrix_np(g))
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, g=g, from_tables=from_tables,
                          act_dtype=act_dtype),
        grid=(m // bm,),
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ),
        interpret=interpret,
    )(*args)
