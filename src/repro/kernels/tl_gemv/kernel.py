"""Pallas kernel: faithful TeLLMe Algorithm-1 table-lookup ternary GEMV.

This is the *faithful* port of the paper's TL-based matmul (G-trit group
indices, 3^G-entry tables built online from the activations, lookup +
accumulate), kept as an oracle/ablation against the production
``ternary_matmul`` kernel — DESIGN.md §2 explains why lookups lose to the MXU
on TPU while being the right call in FPGA LUT-RAM.

Stage structure inside one grid step (grid = (K/bk,), decode GEMV m=1..bm):

  1. table build — the paper's "precompute unit" of 3^G adder/subtractor
     combinations is literally the matmul  A_groups [bm·T, G] @ COMBOS [G, 3^G]
     (T = N/G tables, all built in one MXU call);
  2. lookup-accumulate — TL_TABLE[t, W_idx[t, k]] summed over t, expressed as
     a one-hot contraction so it also lands on the MXU rather than a VPU
     gather (the TPU replacement for URAM multi-port reads).

VMEM: tables [T, 3^G] f32 (e.g. N=4096, G=3 -> 1366×27×4 ≈ 147 KiB),
w_idx block [T, bk] int32, out [bm, bk].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, xs_ref, widx_ref, ws_ref, combos_ref, o_ref, *, g: int):
    bm, n = x_ref.shape
    t = n // g
    bk = widx_ref.shape[1]
    # --- stage 1: build all T tables at once (paper: T parallel LUT banks) ---
    a_groups = x_ref[...].reshape(bm * t, g).astype(jnp.float32)
    tables = jax.lax.dot_general(
        a_groups, combos_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bm, t, 3**g)
    # --- stage 2: lookup-accumulate (one-hot -> MXU) --------------------------
    idx = widx_ref[...]  # [T, bk]
    onehot = (idx[:, :, None] == jnp.arange(3**g, dtype=jnp.int32)[None, None, :]).astype(
        jnp.float32
    )  # [T, bk, 3^g]
    # out[m, k] = sum_t sum_c tables[m, t, c] * onehot[t, k, c]
    acc = jax.lax.dot_general(
        tables.reshape(bm, t * 3**g),
        onehot.transpose(0, 2, 1).reshape(t * 3**g, bk),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # dequant epilogue: per-token activation scale × per-output-channel (or
    # broadcast per-tensor) weight scale row for this K block
    o_ref[...] = acc * xs_ref[...] * ws_ref[...]


@functools.partial(jax.jit, static_argnames=("g", "bk", "interpret"))
def tl_gemv_kernel(
    x_i8: jax.Array,  # [M, N] int8 (M small; decode GEMV)
    x_scale: jax.Array,  # [M, 1] f32
    w_idx: jax.Array,  # [N/g, K] int32 group indices
    w_scale: jax.Array,  # [1, K] f32 per-output-channel scale row
    *,
    g: int = 3,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, n = x_i8.shape
    t, k = w_idx.shape
    assert t * g == n and k % bk == 0 and w_scale.shape == (1, k)
    combos = _combo_const(g)
    return pl.pallas_call(
        functools.partial(_kernel, g=g),
        grid=(k // bk,),
        in_specs=[
            pl.BlockSpec((m, n), lambda j: (0, 0)),
            pl.BlockSpec((m, 1), lambda j: (0, 0)),
            pl.BlockSpec((t, bk), lambda j: (0, j)),
            pl.BlockSpec((1, bk), lambda j: (0, j)),
            pl.BlockSpec((g, 3**g), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m, bk), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=interpret,
    )(x_i8, x_scale, w_idx, w_scale, combos)


@functools.lru_cache(maxsize=None)
def _combo_const(g: int):
    # numpy (not jnp): a cached jnp array created under a jit trace would
    # leak a tracer; numpy constants are safe at any trace depth.
    import numpy as np

    cols = np.arange(3**g)
    digits = []
    rem = cols
    for _ in range(g):
        digits.append((rem % 3) - 1)
        rem = rem // 3
    return np.stack(digits, axis=0).astype(np.float32)  # [g, 3^g]
