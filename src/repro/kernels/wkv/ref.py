"""Oracle for the WKV kernel = the validated jnp chunked form."""

from __future__ import annotations

from ...models.rwkv import _wkv_chunked


def wkv(r, k, v, logw, u, s0, *, chunk: int = 64):
    """r/k/v/logw [B, H, S, n], u [H, n], s0 [B, H, n, n] -> (y, sN)."""
    return _wkv_chunked(r, k, v, logw, u, s0, chunk=chunk)
