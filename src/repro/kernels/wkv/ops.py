"""Jitted wrapper for the WKV chunk kernel.

Note: carries state **from zero** (the training/prefill-from-scratch case,
which is the §Perf cell this kernel targets). A warm incoming state would be
threaded through an extra input block; the jnp path (models/rwkv.py) remains
the general-state implementation and the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import wkv_kernel


def wkv(r, k, v, logw, u, *, chunk: int = 64, interpret=None):
    """r/k/v/logw [B, H, S, n], u [H, n] -> (y [B,H,S,n] f32, sN [B,H,n,n])."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, s, n = r.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2

    def flat(t):
        return t.reshape(b * h, s, n)

    u_full = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, n)
    y, sN = wkv_kernel(flat(r), flat(k), flat(v), flat(logw), u_full,
                       chunk=chunk, interpret=interpret)
    return y.reshape(b, h, s, n), sN.reshape(b, h, n, n)
