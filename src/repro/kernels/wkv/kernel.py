"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence, VMEM-resident decay.

The §Perf analysis of the worst roofline cell (rwkv6-3b × train_4k,
EXPERIMENTS.md) showed the XLA chunked-WKV path is memory-bound on the
O(C²·n) intra-chunk decay tensor, which XLA must materialize in HBM every
chunk (recomputed again in the backward after A1). This kernel is the
TeLLMe-style fusion answer for the attention-free mixer: the decay tensor
(C=64: 64·64·64·4 B = 1 MiB) lives only in VMEM, and HBM traffic per chunk
drops to the r/k/v/w blocks + the [n, n] state — the same
keep-the-intermediate-on-chip move as the paper's fused prefill attention
(C2) applied to the WKV recurrence.

Grid: (B·H, S/C) — chunks iterate fastest; the [n, n] state persists in
VMEM scratch across chunk steps and resets at chunk 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sN_ref, state_ref,
            *, chunk: int, n: int, nc: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    rc = r_ref[0].astype(jnp.float32)  # [C, n]
    kc = k_ref[0].astype(jnp.float32)
    vc = v_ref[0].astype(jnp.float32)
    wc = w_ref[0].astype(jnp.float32)  # log-decay, negative
    u = u_ref[0].astype(jnp.float32)  # [n]

    lc = jnp.cumsum(wc, axis=0)  # inclusive cum-log-decay
    e = lc - wc  # exclusive
    state = state_ref[...]

    # intra-chunk: A[t,s] = Σ_i r_t[i] k_s[i] exp(e_t[i] - lc_s[i]) (s < t)
    # dec lives only in VMEM — never touches HBM (the point of this kernel).
    dec = jnp.exp(e[:, None, :] - lc[None, :, :])  # [C, C, n], ratios ≤ 1
    amat = jnp.sum(rc[:, None, :] * kc[None, :, :] * dec, axis=-1)  # [C, C]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    amat = jnp.where(tri, amat, 0.0)
    diag = jnp.sum(rc * kc * u[None, :], axis=-1)  # [C]

    y = jax.lax.dot_general(amat, vc, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag[:, None] * vc
    y = y + jax.lax.dot_general(rc * jnp.exp(e), state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)

    # state update: S' = diag(exp(lc_last)) S + Σ_s exp(lc_last - lc_s) k_s v_sᵀ
    last = lc[-1]  # [n]
    kdec = kc * jnp.exp(last[None, :] - lc)  # [C, n]
    state = jnp.exp(last)[:, None] * state + jax.lax.dot_general(
        kdec, vc, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_ref[...] = state

    @pl.when(c == nc - 1)
    def _emit_state():
        sN_ref[0] = state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_kernel(
    r: jax.Array,  # [BH, S, n]
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,
    u: jax.Array,  # [BH, n] (pre-expanded per head)
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    bh, s, n = r.shape
    assert s % chunk == 0
    nc = s // chunk
    kern = functools.partial(_kernel, chunk=chunk, n=n, nc=nc)
    return pl.pallas_call(
        kern,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n), lambda b, c: (b, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, n), lambda b, c: (b, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bh, s, n), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
