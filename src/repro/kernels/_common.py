"""Shared wrapper boilerplate for the kernel packages.

Every ``ops.py`` in this tree repeats the same three moves before a
``pallas_call``: resolve ``interpret=None`` to "interpret everywhere but
TPU", flatten the caller's leading batch dims into one row axis, and pad
axes up to block multiples (sliced back off after the call). They live here
once so the policies stay in lockstep across kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> interpret mode everywhere but real TPU (the shared
    default of every kernel wrapper)."""
    return (not on_tpu()) if interpret is None else interpret


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def flatten_lead(x: jax.Array) -> tuple[jax.Array, tuple, int]:
    """[..., N] -> ([M, N], lead_shape, M): one row per leading-dim element."""
    *lead, n = x.shape
    m = 1
    for d in lead:
        m *= d
    return x.reshape(m, n), tuple(lead), m


def pad_to(x: jax.Array, axis: int, target: int) -> jax.Array:
    """Zero-pad ``axis`` up to ``target`` elements (no-op when already there)."""
    n = x.shape[axis]
    if n == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads)
