"""Roofline/cost analysis tooling."""
from . import hlo_cost, roofline  # noqa: F401
