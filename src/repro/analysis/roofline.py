"""Roofline model for TPU v5e: three terms from the compiled dry-run.

    compute_s    = HLO_dot_flops_per_device / peak_FLOPs
    memory_s     = HLO_hbm_bytes_per_device / HBM_bw
    collective_s = collective_bytes_per_device / ICI_link_bw

All three come from the trip-count-aware HLO walker (analysis/hlo_cost) over
the SPMD-partitioned module, so they are per-device values. The dominant
term is the bottleneck; step time ≈ max(terms) on a perfectly-overlapped
machine, and roofline fraction = dominant / sum-if-serialized gives the
headroom estimate we hillclimb in EXPERIMENTS.md §Perf.

MODEL_FLOPS uses the standard 6·N·D (train) / 2·N·D (inference) counting on
*active* parameters (MoE), embedding and attention-map FLOPs excluded — the
ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/dispatch redundancy.
"""

from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip (v5e)
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def terms(dot_flops: float, hbm_bytes: float, collective_bytes: float) -> Roofline:
    return Roofline(
        compute_s=dot_flops / PEAK_FLOPS,
        memory_s=hbm_bytes / HBM_BW,
        collective_s=collective_bytes / ICI_BW,
    )


def model_flops(cfg, shape, *, chips: int) -> dict:
    """Analytic MODEL_FLOPS for one step of this (arch × shape) cell."""
    n_active = cfg.active_param_count_estimate()
    n_total = cfg.param_count_estimate()
    if shape.mode == "train":
        tokens = shape.seq_len * shape.global_batch
        total = 6.0 * n_active * tokens
    elif shape.mode == "prefill":
        tokens = shape.seq_len * shape.global_batch
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return {
        "model_flops_total": total,
        "model_flops_per_device": total / chips,
        "params_total": n_total,
        "params_active": n_active,
    }


def mfu(dot_flops_per_device: float, step_time_s: float) -> float:
    return dot_flops_per_device / (step_time_s * PEAK_FLOPS)
