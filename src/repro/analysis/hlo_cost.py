"""Trip-count-aware cost extraction from optimized HLO text.

``compiled.cost_analysis()`` counts each ``while`` body **once**, so any
program built from ``lax.scan`` (scanned layers, microbatch accumulation,
kv-block streaming) under-reports FLOPs/bytes by the trip count. This walker
parses the optimized HLO, builds the computation call graph with a
per-computation symbol table (operand shapes are not inlined in optimized
HLO), extracts while trip counts (scan counters compare against a constant),
and accumulates:

* ``dot_flops``        — 2 · |out| · |contracting| per dot, × trips
* ``hbm_bytes``        — per *top-level* op in each computation: operand +
                         output bytes (post-fusion, so intra-fusion temps
                         don't count — a faithful HBM-traffic roofline proxy)
* ``collective_bytes`` — per collective op class, × trips (ICI traffic)

All values are **per device** (the HLO is the SPMD-partitioned module).
Validated against analytic FLOP counts in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "f16": 2, "bf16": 2,
    "s16": 2, "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
    "u64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OPCODE_RE = re.compile(r"(?:^|\s)([a-z][a-z0-9\-]*)\(")
_NAME_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    elems = 0
    for _, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
    return elems


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_shape: str
    operands: list
    line: str


@dataclasses.dataclass
class Cost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    coll_count: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.dot_flops += o.dot_flops
        self.hbm_bytes += o.hbm_bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0) + v
        for k, v in o.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(
            self.dot_flops * f,
            self.hbm_bytes * f,
            self.collective_bytes * f,
            {k: v * f for k, v in self.coll_by_op.items()},
            {k: v * f for k, v in self.coll_count.items()},
        )


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.ops: list[OpInfo] = []
        self.shapes: dict[str, str] = {}  # op name -> output shape string


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        stripped = line.strip()
        if not line.startswith(" ") and "->" in line and stripped.endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if stripped == "}" or line.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        lhs, rhs = line.split("=", 1)
        name = lhs.replace("ROOT", "").strip().lstrip("%")
        rhs = rhs.strip()
        padded = " " + rhs
        mo = _OPCODE_RE.search(padded)
        if not mo:
            continue
        opcode = mo.group(1)
        shape_str = padded[: mo.start(1)].strip()
        # operand names: inside the first balanced paren group after opcode
        paren_start = mo.end() - 1  # index of "(" in padded
        depth = 0
        end = paren_start
        for i in range(paren_start, len(padded)):
            if padded[i] == "(":
                depth += 1
            elif padded[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = padded[paren_start + 1 : end]
        operands = _NAME_RE.findall(operand_str)
        op = OpInfo(name, opcode, shape_str, operands, line)
        cur.ops.append(op)
        cur.shapes[name] = shape_str
    return comps


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _trip_count(comp: Computation | None) -> int:
    if comp is None:
        return 1
    best = 1
    for op in comp.ops:
        m = re.search(r"constant\((-?[0-9]+)\)", op.line)
        if m:
            best = max(best, int(m.group(1)))
    return best


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "iota", "bitcast-convert",
}


def analyze(hlo: str, entry: str | None = None) -> Cost:
    comps = parse_computations(hlo)
    if not comps:
        return Cost()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.MULTILINE)
        entry = m.group(1) if m else max(comps, key=lambda k: len(comps[k].ops))

    memo_flops: dict[str, float] = {}

    def comp_dot_flops(cname: str) -> float:
        """Recursive dot flops of a computation (used for fusion bodies)."""
        if cname in memo_flops:
            return memo_flops[cname]
        memo_flops[cname] = 0.0
        comp = comps.get(cname)
        total = 0.0
        if comp:
            for op in comp.ops:
                if op.opcode == "dot":
                    total += _dot_flops(op, comp)
                elif op.opcode == "fusion":
                    mcalls = re.search(r"calls=%?([\w\.\-]+)", op.line)
                    if mcalls:
                        total += comp_dot_flops(mcalls.group(1))
        memo_flops[cname] = total
        return total

    def _dot_flops(op: OpInfo, comp: Computation) -> float:
        out_elems = _shape_elems(op.out_shape)
        if not op.operands:
            return 0.0
        lhs_shape = comp.shapes.get(op.operands[0], "")
        lhs_dims = _shape_dims(lhs_shape)
        c = _CONTRACT_RE.search(op.line)
        contract = [int(i) for i in c.group(1).split(",")] if (c and c.group(1)) else []
        k = 1
        for i in contract:
            if i < len(lhs_dims):
                k *= lhs_dims[i]
        return 2.0 * out_elems * k

    def _operand_bytes(op: OpInfo, comp: Computation) -> float:
        return sum(_shape_bytes(comp.shapes.get(o, "")) for o in op.operands)

    memo_cost: dict[str, Cost] = {}

    def comp_cost(cname: str) -> Cost:
        if cname in memo_cost:
            return memo_cost[cname]
        memo_cost[cname] = Cost()  # cycle break
        comp = comps.get(cname)
        cost = Cost()
        if comp is None:
            return cost
        for op in comp.ops:
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.line)
                trips = _trip_count(comps.get(mc.group(1))) if mc else 1
                if mb:
                    cost += comp_cost(mb.group(1)).scaled(trips)
                continue
            if op.opcode == "call":
                mcalls = re.search(r"to_apply=%?([\w\.\-]+)", op.line)
                if mcalls:
                    cost += comp_cost(mcalls.group(1))
                continue
            if op.opcode == "conditional":
                mb = re.findall(r"branch_computations=\{([^}]*)\}", op.line)
                if mb:
                    branches = [comp_cost(b.strip().lstrip("%")) for b in mb[0].split(",")]
                    cost += max(branches, key=lambda c: c.dot_flops + c.hbm_bytes)
                continue
            if op.opcode == "fusion" or op.opcode == "dynamic-update-slice":
                if op.opcode == "fusion":
                    mcalls = re.search(r"calls=%?([\w\.\-]+)", op.line)
                    if mcalls:
                        cost.dot_flops += comp_dot_flops(mcalls.group(1))
                out_b = _shape_bytes(op.out_shape)
                opnd_b = [_shape_bytes(comp.shapes.get(o, "")) for o in op.operands]
                if op.opcode == "dynamic-update-slice" or "dynamic-update-slice" in op.name:
                    # in-place slice update: the full buffer is aliased, only
                    # the update slice is truly read+written.
                    aliased = next((b for b in opnd_b if b == out_b), 0.0)
                    if aliased:
                        cost.hbm_bytes += sum(opnd_b) - aliased + (out_b - aliased)
                        continue
                cost.hbm_bytes += out_b + sum(opnd_b)
                continue
            if op.opcode == "dot":
                cost.dot_flops += _dot_flops(op, comp)
                cost.hbm_bytes += _shape_bytes(op.out_shape) + _operand_bytes(op, comp)
                continue
            matched = None
            for coll in COLLECTIVES:
                if op.opcode in (coll, coll + "-start", coll + "-done"):
                    matched = coll
                    break
            if matched:
                if op.opcode.endswith("-done"):
                    continue  # counted at -start
                b = _shape_bytes(op.out_shape)
                if op.opcode.endswith("-start"):
                    b = b / 2  # start ops carry (operand, result) tuples
                cost.collective_bytes += b
                cost.coll_by_op[matched] = cost.coll_by_op.get(matched, 0) + b
                cost.coll_count[matched] = cost.coll_count.get(matched, 0) + 1
                cost.hbm_bytes += b + _operand_bytes(op, comp)
                continue
            if op.opcode in _SKIP_BYTES_OPS:
                continue
            cost.hbm_bytes += _shape_bytes(op.out_shape) + _operand_bytes(op, comp)
        memo_cost[cname] = cost
        return cost

    return comp_cost(entry)
