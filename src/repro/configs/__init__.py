"""Architecture configs: 10 assigned archs + the paper's own 0.7B model."""

from .base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
    default_parallel,
    get_config,
    get_parallel_config,
    list_archs,
    resolve_slo,
)
