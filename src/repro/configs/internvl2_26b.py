"""InternVL2-26B — InternViT frontend (stubbed) + InternLM2 backbone.

[arXiv:2404.16821; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553. ``input_specs`` provides precomputed patch embeddings.
"""

from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        frontend="vision",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        frontend="vision",
    )


register("internvl2-26b", full, smoke)
