"""TeLLMe's own deployment target: BitNet-b1.58 0.7B (paper Table V row).

d_model=1536 (the paper's LM-head example: N=1536, V=32000), 24 layers,
ternary weights + int8 activations — the model the KV260 numbers are
measured on. This config anchors the paper-metric benchmarks
(compression ratio, prefill/decode boundedness, throughput model).
"""

from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="tellme-0.7b",
        family="dense",
        n_layers=24,
        d_model=1536,
        n_heads=16,
        n_kv_heads=16,
        head_dim=96,
        d_ff=4096,
        vocab_size=32000,
        # chunked prefill: the paper's 64/128-token prompt latency points sit
        # on the two small buckets; 256 covers long-prompt chunking. One tick
        # admits up to 512 chunk-tokens next to the decode step.
        prefill_chunk_sizes=(64, 128, 256),
        prefill_chunk_budget=512,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="tellme-0.7b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        prefill_chunk_sizes=(64, 128, 256),
        prefill_chunk_budget=256,
    )


register("tellme-0.7b", full, smoke)
