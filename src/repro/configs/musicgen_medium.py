"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (GQA kv=24 ⇒ MHA) d_ff=6144
vocab=2048. Audio frontend stubbed: ``input_specs`` provides precomputed
EnCodec frame embeddings (backbone-only scope per the shape spec).
"""

from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="dense",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        frontend="audio",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        frontend="audio",
    )


register("musicgen-medium", full, smoke)
