"""Snowflake Arctic (480B) — 128-expert top-2 MoE + parallel dense residual.

[hf:Snowflake/snowflake-arctic-base] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000; every layer: attention + (dense residual MLP ∥ MoE).
"""

from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        n_experts=128,
        experts_per_tok=2,
        dense_residual=True,
        dense_ff=4864,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=48,
        vocab_size=256,
        n_experts=8,
        experts_per_tok=2,
        dense_residual=True,
        dense_ff=48,
    )


register("arctic-480b", full, smoke)
