"""Gemma-2 27B — alternating local/global attention + logit softcapping.

[arXiv:2408.00118; hf] 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; sliding window 4096 on local layers (period 2), attention
softcap 50, final softcap 30.

``long_500k`` is *skipped*: half the layers are global full attention, so the
stack is not sub-quadratic (DESIGN.md §5).
"""

from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        sliding_window=4096,
        local_global_period=2,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        sliding_window=16,
        local_global_period=2,
    )


register("gemma2-27b", full, smoke)
