"""DeepSeek-V2-Lite (16B total / 2.4B active) — MLA + fine-grained MoE.

[arXiv:2405.04434; hf] 27L d_model=2048 16H d_ff(expert)=1408 vocab=102400,
MLA kv_lora_rank=512 (qk_nope 128 / qk_rope 64 / v 128), 64 routed experts
top-6 + 2 shared experts, first layer dense (d_ff 10944).
"""

from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="mla_moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        n_experts=64,
        experts_per_tok=6,
        n_shared_experts=2,
        shared_expert_ff=1408,
        first_dense_layers=1,
        dense_ff=10944,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke",
        family="mla_moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=48,
        vocab_size=256,
        n_experts=8,
        experts_per_tok=2,
        n_shared_experts=1,
        shared_expert_ff=48,
        first_dense_layers=1,
        dense_ff=128,
        kv_lora_rank=32,
        qk_nope_head_dim=16,
        qk_rope_head_dim=8,
        v_head_dim=16,
    )


register("deepseek-v2-lite-16b", full, smoke)
