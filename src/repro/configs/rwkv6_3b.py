"""RWKV-6 "Finch" 3B — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; hf] 32L d_model=2560 d_ff=8960 vocab=65536, head size 64
(40 heads). O(1) decode state; ``long_500k`` runs. TeLLMe C2 inapplicable
(attention-free) — see DESIGN.md §5.
"""

from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65536,
        rwkv_head_dim=64,
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        rwkv_head_dim=16,
        sub_quadratic=True,
    )


register("rwkv6-3b", full, smoke)
