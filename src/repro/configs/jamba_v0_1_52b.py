"""Jamba-v0.1 (52B) — hybrid Mamba+attention (1:7) with 16-expert MoE.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; attention every 8th layer (offset 4), MoE every 2nd layer
(16 experts, top-2), Mamba d_state=16 d_conv=4 expand=2.

Sub-quadratic (mamba layers + 4 attention layers) ⇒ ``long_500k`` runs.
"""

from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        n_experts=16,
        experts_per_tok=2,
        moe_every=2,
        attn_layer_period=8,
        attn_layer_offset=4,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        n_experts=4,
        experts_per_tok=2,
        moe_every=2,
        attn_layer_period=4,
        attn_layer_offset=2,
        mamba_d_state=8,
        mamba_d_conv=4,
        mamba_expand=2,
        sub_quadratic=True,
    )


register("jamba-v0.1-52b", full, smoke)
