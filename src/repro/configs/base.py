"""Configuration system: model / parallelism / run-shape configs + registry.

Every assigned architecture registers a ``ModelConfig`` (exact public
hyper-parameters) plus a reduced ``smoke`` twin for CPU tests. Input shapes
(the 4 assigned cells) are ``ShapeConfig``s; ``input_specs`` derives
ShapeDtypeStruct stand-ins for the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | mla_moe | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    shared_expert_ff: int = 0
    first_dense_layers: int = 0  # deepseek: layer 0 is dense
    dense_ff: int = 0  # dense FFN width (first layers / arctic residual)
    dense_residual: bool = False  # arctic: dense FFN parallel to MoE
    moe_every: int = 1  # MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    # --- MLA (deepseek) ------------------------------------------------------
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- gemma2 --------------------------------------------------------------
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    sliding_window: int = 0
    local_global_period: int = 0  # alternate local/global attention every k
    # --- hybrid / ssm ---------------------------------------------------------
    attn_layer_period: int = 0  # jamba: 1 attention layer per period
    attn_layer_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_dim: int = 64
    # --- frontend --------------------------------------------------------------
    frontend: str = "none"  # none | audio | vision
    # --- quantization (the paper's technique) -----------------------------------
    ternary: bool = True
    act_bits: int = 8
    # KV-cache residency dtype (attention mixers only). "int8" stores the
    # cache as int8 with per-(slot, head, row) f32 absmax scales — the
    # paper's QDQ unit applied to the cache stream, halving attention-phase
    # HBM bytes. "bf16" (default) keeps every pre-existing path bit-identical.
    kv_cache_dtype: str = "bf16"  # bf16 | int8
    # KV-cache residency *layout* (DESIGN.md §paged-kv). "contiguous" keeps
    # per-slot [B, HK, S, D] rows (every pre-existing path, bit-identical).
    # "paged" stores K/V in a device-resident page pool [P, HK, ps, D] (int8
    # scale side arrays page along) addressed through a per-slot page table —
    # a ServingEngine concern only: generate()/forward stay contiguous.
    kv_layout: str = "contiguous"  # contiguous | paged
    kv_page_size: int = 64  # tokens per page; must divide prefill_chunk_sizes[0]
    kv_num_pages: int = 0   # pool size; 0 = auto (slots * cache_len / page_size)
    # Radix-style shared-prefix reuse at admission (paged layout only):
    # full prompt pages are interned in a trie so later requests sharing a
    # system prompt map those pages read-only and prefill only the tail.
    prefix_cache: bool = True
    # Ternary matmul engine (DESIGN.md §table-lookup). "packed" pins the
    # 2-bit-planar Pallas kernels; "tl" forces the table-lookup engine
    # (paper's Algorithm 1: grouped activation tables + index gather);
    # "auto" resolves per matmul shape from the autotuner's measured
    # TL-vs-packed timings, falling back to packed when never benchmarked.
    matmul_engine: str = "auto"  # packed | tl | auto
    # --- serving: chunked prefill / continuous batching --------------------------
    # Prompts are split into chunks drawn from this grid (each size must divide
    # every larger one), so the engine compiles exactly len(sizes) prefill
    # shapes — ever. The budget caps chunk-tokens processed per scheduler tick
    # alongside the decode step, bounding decode stall under concurrent prefill.
    prefill_chunk_sizes: tuple = (64, 128, 256)
    prefill_chunk_budget: int = 512
    # --- serving: speculative decoding (DESIGN.md §speculative) ------------------
    # γ tokens drafted per decoding slot and verified in one chunked forward
    # through the prefill_append path; model-free prompt-lookup ("ngram")
    # drafting matches the longest n-gram suffix (n ≤ spec_ngram_max) of the
    # slot's prompt+emitted history against itself and proposes the
    # continuation. Off by default — ServingEngine(speculative=True) opts in.
    spec_gamma: int = 4
    spec_draft: str = "ngram"  # DRAFTERS registry key (serving/speculative.py)
    spec_ngram_max: int = 3
    # --- serving: resilience (DESIGN.md §resilience) -----------------------------
    # Bounded admission queue (0 = unbounded; submit() rejects with FAILED /
    # "queue_full" past the cap) and a default per-request TTL in seconds
    # (0 = none; Request.deadline_s overrides). Speculative ticks auto-disable
    # once >= spec_disable_after tokens have been drafted with an aggregate
    # acceptance rate below spec_min_acceptance — collapsed acceptance means
    # each γ+1-row verify forward is pure overhead.
    admission_queue_cap: int = 0
    request_ttl_s: float = 0.0
    spec_min_acceptance: float = 0.05
    spec_disable_after: int = 64
    # Engine event-log ring size (0 = unbounded): stats() bookkeeping on a
    # long-lived server stays fixed-size, with a dropped-events counter.
    stats_ring_events: int = 4096
    # --- serving: SLO classes + replica pool (DESIGN.md §replica-pool) -----------
    # Admission classes for the multi-replica pool: (name, priority,
    # default_deadline_s, chunk_budget_weight) tuples — tuple-of-tuples so the
    # frozen config stays hashable. The class maps onto the PR-7 lifecycle
    # fields (priority feeds preemption + queue order, deadline_s the TTL; a
    # 0.0 deadline means "none — fall back to request_ttl_s") and its weight
    # scales the engine's per-tick prefill_chunk_budget while a request of
    # that class is the highest class mid-prefill: interactive prefills at
    # full pace, batch/best_effort yield tick capacity to co-batched decode.
    slo_classes: tuple = (
        ("interactive", 2, 0.0, 1.0),
        ("batch", 1, 0.0, 0.5),
        ("best_effort", 0, 0.0, 0.25),
    )
    # Replica-pool health gating / failover (serving/pool.py). A replica is
    # drained + quarantined (never hard-removed) after pool_health_fail_ticks
    # consecutive failed engine ticks or a dense straggler window
    # (pool_straggler_events flagged among the last pool_straggler_window
    # ticks); reinstatement probes run after an exponential backoff
    # (pool_backoff_s doubling to pool_backoff_max_s). A driver thread whose
    # heartbeat goes stale for pool_hang_timeout_s is declared hung and its
    # live requests are migrated like a crash.
    pool_replicas: int = 2
    pool_health_fail_ticks: int = 3
    pool_backoff_s: float = 0.25
    pool_backoff_max_s: float = 8.0
    pool_hang_timeout_s: float = 2.0
    pool_probe_timeout_s: float = 10.0
    pool_poll_interval_s: float = 0.01
    pool_straggler_window: int = 8
    pool_straggler_events: int = 3
    # --- serving: async front door (DESIGN.md §serving-frontdoor) ----------------
    # HTTP/SSE server defaults (launch/server.py overrides per flag). The
    # drain timeout is the SIGTERM hard-kill ceiling: in-flight requests get
    # this long to finish or deadline-out before the server cancels them.
    server_host: str = "127.0.0.1"
    server_port: int = 8080
    server_drain_timeout_s: float = 30.0
    server_poll_s: float = 0.001  # driver-thread idle poll between ticks
    # --- serving: open-loop traffic benchmark (benchmarks/bench_serving.py) ------
    # Poisson arrival-rate sweep (requests/s) and per-rate request count for
    # the latency-under-load report; --smoke shrinks both.
    bench_arrival_rates: tuple = (2.0, 6.0, 18.0)
    bench_requests_per_rate: int = 24
    # --- numerics ----------------------------------------------------------------
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    dtype: Any = jnp.bfloat16
    sub_quadratic: bool = False  # True for ssm/hybrid: long_500k runnable

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 for TP divisibility + MXU alignment."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count_estimate(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d = self.d_model
        total = self.padded_vocab * d * 2  # embed + head (untied)
        for i in range(self.n_layers):
            total += _layer_params(self, i)
        return total

    def active_param_count_estimate(self) -> int:
        d = self.d_model
        total = self.padded_vocab * d * 2
        for i in range(self.n_layers):
            total += _layer_params(self, i, active_only=True)
        return total


def _layer_params(cfg: ModelConfig, i: int, *, active_only: bool = False) -> int:
    d = cfg.d_model
    n = 0
    is_attn = True
    if cfg.family == "hybrid":
        is_attn = (i % cfg.attn_layer_period) == cfg.attn_layer_offset
    if cfg.family == "ssm":
        is_attn = False
    # attention / mixer
    if cfg.family == "ssm":
        n += 4 * d * d + d * d  # r/k/v/g/o
        n += d * cfg.d_ff * 2 + d * d  # channel mix
        return n
    if is_attn:
        if cfg.kv_lora_rank:
            qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            n += d * cfg.n_heads * qk
            n += d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            n += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
            n += cfg.n_heads * cfg.v_head_dim * d
        else:
            hd = cfg.head_dim
            n += d * cfg.n_heads * hd  # q
            n += 2 * d * cfg.n_kv_heads * hd  # kv
            n += cfg.n_heads * hd * d  # o
    else:
        di = cfg.mamba_expand * d
        n += d * 2 * di + di * d + di * (max(d // 16, 8) + 2 * cfg.mamba_d_state)
    # ffn
    moe_layer = (
        cfg.n_experts > 0
        and i >= cfg.first_dense_layers
        and (i % cfg.moe_every) == (cfg.moe_every - 1 if cfg.moe_every > 1 else 0)
    )
    if moe_layer:
        e = cfg.experts_per_tok if active_only else cfg.n_experts
        n += e * 3 * d * cfg.d_ff
        if cfg.n_shared_experts:
            n += 3 * d * (cfg.shared_expert_ff or cfg.d_ff) * cfg.n_shared_experts
        if cfg.dense_residual:
            n += 3 * d * (cfg.dense_ff or cfg.d_ff)
    else:
        ff = cfg.dense_ff if (cfg.n_experts and cfg.dense_ff) else cfg.d_ff
        if cfg.family != "ssm":
            n += 3 * d * ff
    return n


def resolve_slo(cfg: ModelConfig, name: str) -> tuple[int, float | None, float]:
    """Map an SLO class name onto the lifecycle fields: ``(priority,
    deadline_s | None, chunk_budget_weight)``. A 0.0 class deadline resolves
    to ``None`` (the engine then applies ``cfg.request_ttl_s``). Unknown
    class names raise — a typo'd class must be an admission-time 400, not a
    silent best_effort demotion."""
    for cls, prio, deadline, weight in cfg.slo_classes:
        if cls == name:
            return int(prio), (float(deadline) if deadline else None), float(weight)
    raise KeyError(f"unknown SLO class {name!r}; "
                   f"have {[c[0] for c in cfg.slo_classes]}")


# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    fsdp_pod: bool = False  # extend FSDP over the pod axis (100B+ models)
    seq_shard: bool = False  # SP over the model axis for long sequences
    remat: str = "full"  # none | full | dots
    microbatches: int = 1
    scan_layers: bool = True
    opt_state_dtype: str = "float32"  # bfloat16 for the largest models
    param_dtype: str = "float32"
    moe_group_size: int = 1024


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}
_PARALLEL: dict[str, Callable[[str], ParallelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig],
             parallel: Callable[[str], ParallelConfig] | None = None):
    _REGISTRY[name] = full
    _SMOKE[name] = smoke
    if parallel:
        _PARALLEL[name] = parallel


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def get_parallel_config(name: str, shape: str) -> ParallelConfig:
    _ensure_loaded()
    if name in _PARALLEL:
        return _PARALLEL[name](shape)
    return default_parallel(get_config(name), SHAPES[shape])


def default_parallel(cfg: ModelConfig, shape: ShapeConfig) -> ParallelConfig:
    big = cfg.param_count_estimate() > 30e9
    return ParallelConfig(
        fsdp_pod=big,
        # SP: long sequences always; training also for wide residual streams
        # (saved layer inputs scale with d_model — llama3-405B needs seq
        # sharded even at 4k).
        seq_shard=(shape.seq_len >= 32768 and shape.mode != "decode")
        or (shape.mode == "train" and cfg.d_model >= 6144),
        remat="full" if shape.mode == "train" else "none",
        microbatches=_default_microbatches(cfg, shape),
        opt_state_dtype="bfloat16" if big else "float32",
    )


def _default_microbatches(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if shape.mode != "train":
        return 1
    # Per-device tokens ride the data axis (16-way); aim ≲ 8k tokens/device
    # per microbatch at d_model 4k, shrinking for wider models. mb must keep
    # the per-microbatch global batch divisible by the largest DP degree (32,
    # the 2-pod mesh) so both meshes shard cleanly.
    tokens = shape.seq_len * shape.global_batch
    per_dev = tokens / 16
    width_scale = max(cfg.d_model / 4096.0, 1.0)
    target = max(int(8192 / width_scale), 1024)
    mb = max(int(per_dev / target), 1)
    mb_cap = max(shape.global_batch // 32, 1)
    mb = min(mb, mb_cap)
    while mb_cap % mb:
        mb -= 1
    return max(mb, 1)


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        arctic_480b,
        deepseek_v2_lite_16b,
        gemma2_27b,
        granite_8b,
        internlm2_20b,
        internvl2_26b,
        jamba_v0_1_52b,
        llama3_405b,
        musicgen_medium,
        rwkv6_3b,
        tellme_0p7b,
    )
